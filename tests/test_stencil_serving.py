"""Tier-1 tests for the batched multi-domain stencil serving tier.

Contracts pinned here (the serving_sweep.py gates, at test-sized grids):

  * `advect_fused_batched` (the vmap mega-launch) is BITWISE-equal to
    per-domain sequential `advect_fused` runs — Pallas prepends the slot
    index to the grid, so slots stream back-to-back through the same
    VMEM rings and the startup masking walls off stale ring content.
  * `StencilServingEngine` pads mixed-extent requests into fixed slots
    with interior masks freezing every padded cell at exactly 0.0 update,
    so streamed states and final outputs are bitwise-equal to unpadded
    sequential runs.
  * the compiled-executable cache traces once per (shape, T, dtype,
    n_blocks, exchange, mesh) key; a simulated device loss re-shards to
    fewer slots mid-run with bitwise-identical results and exactly one
    extra recorded miss.
  * `serving_throughput_model` rises strictly with batch until the VMEM
    ring budget binds, then refuses.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import roofline as R
from repro.kernels.advection.advection import (advect_fused,
                                               advect_fused_batched,
                                               fused_register_bytes,
                                               hbm_bytes_model)
from repro.kernels.advection.ref import default_params
from repro.serving.stencil_engine import (ExecutableCache, StencilRequest,
                                          StencilServingEngine)
from repro.stencil.advection import AdvectionDomain, stratus_fields
from repro.stencil.distributed import count_pallas_hbm_bytes

X, Y, Z, T = 8, 10, 16, 2
DT = 0.005


def _dom(**kw):
    kw.setdefault("variant", "fused")
    kw.setdefault("fuse_T", T)
    kw.setdefault("dt", DT)
    return AdvectionDomain(X, Y, Z, **kw)


def _req(uid, Xr, Yr, n_steps=1):
    u, v, w = stratus_fields(Xr, Yr, Z, seed=uid)
    return StencilRequest(uid=uid, u=np.asarray(u), v=np.asarray(v),
                          w=np.asarray(w), n_steps=n_steps)


def _sequential(uid, Xr, Yr, n_steps):
    p = default_params(Z)
    u, v, w = stratus_fields(Xr, Yr, Z, seed=uid)
    states = []
    for _ in range(n_steps):
        u, v, w = advect_fused(u, v, w, p, T=T, dt=DT, interpret=True)
        states.append(tuple(np.asarray(a) for a in (u, v, w)))
    return states


# -- the batched kernel ----------------------------------------------------

def test_batched_kernel_bitwise_equals_sequential():
    p = default_params(Z)
    doms = [stratus_fields(X, Y, Z, seed=s) for s in range(3)]
    u, v, w = (jnp.stack([d[i] for d in doms]) for i in range(3))
    ou, ov, ow = advect_fused_batched(u, v, w, p, T=T, dt=DT, interpret=True)
    for b, (du, dv, dw) in enumerate(doms):
        su, sv, sw = advect_fused(du, dv, dw, p, T=T, dt=DT, interpret=True)
        for got, ref in ((ou[b], su), (ov[b], sv), (ow[b], sw)):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_batched_kernel_rejects_rank3():
    p = default_params(Z)
    u, v, w = stratus_fields(X, Y, Z, seed=0)
    with pytest.raises(ValueError, match="slot-stacked"):
        advect_fused_batched(u, v, w, p, T=T, dt=DT, interpret=True)


def test_counted_hbm_bytes_scale_with_batch():
    # lane-aligned Z so lane_eff == 1 and the count matches EXACTLY
    Zl = 128
    p = default_params(Zl)
    for B in (1, 2):
        u, v, w = (jnp.stack([stratus_fields(4, 8, Zl, seed=s)[i]
                              for s in range(B)]) for i in range(3))

        def fn(uu, vv, ww):
            return advect_fused_batched(uu, vv, ww, p, T=T, dt=DT,
                                        interpret=True)

        counted = count_pallas_hbm_bytes(fn, u, v, w)
        assert counted == B * hbm_bytes_model(4, 8, Zl, 4, "fused", T=T)


# -- the serving engine ----------------------------------------------------

def test_engine_padded_mixed_extents_bitwise():
    sizes = [(X, Y, 2), (5, 6, 1), (4, 8, 3)]
    eng = StencilServingEngine(_dom(), batch_size=2)
    done = eng.run([_req(i, xr, yr, n) for i, (xr, yr, n) in enumerate(sizes)])
    assert set(done) == {0, 1, 2}
    for i, (xr, yr, n) in enumerate(sizes):
        ref = _sequential(i, xr, yr, n)
        assert len(done[i].states) == n          # streamed every mega-step
        for got, want in zip(done[i].states, ref):
            for g, r in zip(got, want):
                assert g.shape == (xr, yr, Z)
                np.testing.assert_array_equal(np.asarray(g), r)
        for g, r in zip(done[i].out, ref[-1]):
            np.testing.assert_array_equal(np.asarray(g), r)


def test_engine_zero_steps_completes_at_prime():
    eng = StencilServingEngine(_dom(), batch_size=2)
    r = _req(0, 5, 6, n_steps=0)
    done = eng.run([r])
    assert done[0].states == []
    np.testing.assert_array_equal(done[0].out[0], r.u)
    assert not eng.slots.any_live()
    assert eng.cache_stats()["misses"] == 0      # never launched


def test_engine_validates_requests():
    eng = StencilServingEngine(_dom(), batch_size=1)
    u, v, w = (np.zeros((5, 6, Z), np.float32) for _ in range(3))
    with pytest.raises(ValueError, match="n_steps"):
        eng.run([StencilRequest(uid=0, u=u, v=v, w=w, n_steps=-1)])
    big = np.zeros((X + 1, Y, Z), np.float32)
    with pytest.raises(ValueError, match="slot"):
        eng.run([StencilRequest(uid=1, u=big, v=big, w=big, n_steps=1)])
    zbad = np.zeros((5, 6, Z + 8), np.float32)
    with pytest.raises(ValueError, match="lane"):
        eng.run([StencilRequest(uid=2, u=zbad, v=zbad, w=zbad, n_steps=1)])


def test_executable_cache_traces_once():
    sizes = [(X, Y, 2), (5, 6, 1), (4, 8, 3), (6, 6, 2)]
    eng = StencilServingEngine(_dom(), batch_size=2)
    eng.run([_req(i, xr, yr, n) for i, (xr, yr, n) in enumerate(sizes)])
    stats = eng.cache_stats()
    assert stats["misses"] == 1 and stats["entries"] == 1
    assert stats["hits"] >= 2                    # every later mega-step hit


def test_cache_unit():
    c = ExecutableCache()
    calls = []
    f = c.get("k1", lambda: calls.append(1) or (lambda: 7))
    g = c.get("k1", lambda: calls.append(1) or (lambda: 9))
    assert f is g and calls == [1]
    assert c.stats() == {"hits": 1, "misses": 1, "entries": 1,
                         "evictions": 0}


def test_device_loss_reshard_bitwise_resume():
    sizes = [(X, Y, 3), (5, 6, 2), (4, 8, 3)]
    reqs = lambda: [_req(i, xr, yr, n)
                    for i, (xr, yr, n) in enumerate(sizes)]
    clean = StencilServingEngine(_dom(), batch_size=2)
    done = clean.run(reqs())
    faulted = StencilServingEngine(_dom(), batch_size=2)
    done_f = faulted.run(reqs(), lose_device_at=1, reshard_to=1)
    assert set(done_f) == set(done)
    for i in done:
        for g, r in zip(done_f[i].out, done[i].out):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
    # the re-shard re-traces for the new batch size: exactly one extra miss
    assert faulted.cache_stats()["misses"] == 2
    assert faulted.cache_stats()["entries"] == 2


# -- the throughput model --------------------------------------------------

def test_serving_throughput_strictly_rises_to_vmem_bound():
    ring = fused_register_bytes(T, Y, Z, 4)
    max_b = R.serving_max_batch(ring)
    assert max_b >= 2
    tputs = [R.serving_throughput_model(b, hbm_bytes_per_domain=1e6,
                                        ring_bytes_per_slot=ring)
             for b in range(1, max_b + 1)]
    assert all(b > a for a, b in zip(tputs, tputs[1:]))
    with pytest.raises(ValueError, match="VMEM"):
        R.serving_throughput_model(max_b + 1, hbm_bytes_per_domain=1e6,
                                   ring_bytes_per_slot=ring)


def test_serving_max_batch_rejects_oversized_ring():
    with pytest.raises(ValueError):
        R.serving_max_batch(R.VMEM_PER_CORE + 1)


def test_domain_batch_accounting_scales_linearly():
    one = _dom(batch=1)
    four = _dom(batch=4)
    assert four.flops_per_step() == 4 * one.flops_per_step()
    assert four.hbm_bytes_per_step() == 4 * one.hbm_bytes_per_step()
    assert four.vmem_register_bytes() == 4 * one.vmem_register_bytes()
    with pytest.raises(ValueError):
        _dom(batch=0)


def test_modelled_throughput_matches_domain_method():
    eng = StencilServingEngine(_dom(), batch_size=2)
    want = dataclasses.replace(_dom(), batch=2).serving_throughput()
    assert eng.modelled_throughput() == want

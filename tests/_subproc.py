"""Shared runner for multi-device subprocess tests.

The device count must be fixed by XLA_FLAGS before jax initialises, so
multi-device cases run their code in a child interpreter. The env contract
lives HERE, once: JAX_PLATFORMS=cpu is pinned both in the child env and
(belt-and-braces) by the code blocks themselves — without it the scrubbed
env lets jax probe a TPU backend and libtpu burns ~2 minutes on
GCP-metadata retries before the CPU fallback (the old timeout flake).

Failures re-raise WITH the child's captured stdout+stderr: a bare
returncode assert hides the actual shard_map traceback, and a timeout
used to discard everything the child printed before hanging.
"""
import subprocess
import sys

ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
       "JAX_PLATFORMS": "cpu"}


def _report(label: str, r_stdout: str, r_stderr: str) -> str:
    return (f"{label}\n"
            f"--- child stdout (tail) ---\n{(r_stdout or '')[-2000:]}\n"
            f"--- child stderr (tail) ---\n{(r_stderr or '')[-3000:]}")


def run_ok(code: str, timeout: int = 600) -> None:
    """Run `code` in a child interpreter; assert exit 0 and an OK sentinel
    (so a child that dies before its asserts still fails the test). On a
    nonzero exit or a timeout the raised error carries the child's
    captured stdout AND stderr, so the real traceback survives."""
    assert ENV.get("JAX_PLATFORMS") == "cpu", (
        "subprocess env contract broken: JAX_PLATFORMS=cpu must be pinned "
        f"in the child env, got {ENV!r}")
    try:
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, cwd=".",
                           timeout=timeout, env=dict(ENV))
    except subprocess.TimeoutExpired as e:
        out = e.stdout.decode() if isinstance(e.stdout, bytes) else e.stdout
        err = e.stderr.decode() if isinstance(e.stderr, bytes) else e.stderr
        raise AssertionError(
            _report(f"child timed out after {timeout}s", out, err)) from e
    if r.returncode != 0:
        raise AssertionError(
            _report(f"child exited {r.returncode}", r.stdout, r.stderr))
    if "OK" not in r.stdout:
        raise AssertionError(
            _report("child exited 0 without printing the OK sentinel",
                    r.stdout, r.stderr))

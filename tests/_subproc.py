"""Shared runner for multi-device subprocess tests.

The device count must be fixed by XLA_FLAGS before jax initialises, so
multi-device cases run their code in a child interpreter. The env contract
lives HERE, once: JAX_PLATFORMS=cpu is pinned both in the child env and
(belt-and-braces) by the code blocks themselves — without it the scrubbed
env lets jax probe a TPU backend and libtpu burns ~2 minutes on
GCP-metadata retries before the CPU fallback (the old timeout flake).
"""
import subprocess
import sys

ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
       "JAX_PLATFORMS": "cpu"}


def run_ok(code: str, timeout: int = 600) -> None:
    """Run `code` in a child interpreter; assert exit 0 and an OK sentinel
    (so a child that dies before its asserts still fails the test)."""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=".", timeout=timeout, env=dict(ENV))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout, r.stdout[-2000:]

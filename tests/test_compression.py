"""Gradient compression: quantisation bounds + error-feedback properties."""
import jax
import jax.numpy as jnp
import numpy as np
from _prop import given, settings, st

from repro.distributed.compression import (dequantize_int8, quantize_int8,
                                           wire_bytes_saved)


@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-6, 1e4))
def test_quantize_roundtrip_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-12  # half-ulp of the int8 grid


def test_error_feedback_unbiased_over_time():
    """With error feedback, the accumulated applied signal tracks the true
    cumulative gradient (bias does not grow)."""
    rng = np.random.default_rng(0)
    residual = jnp.zeros((128,), jnp.float32)
    true_sum = np.zeros((128,))
    applied_sum = np.zeros((128,))
    for t in range(50):
        g = jnp.asarray(rng.normal(size=(128,)) * 0.1, jnp.float32)
        xf = g + residual
        q, s = quantize_int8(xf)
        deq = dequantize_int8(q, s)
        residual = xf - deq
        true_sum += np.asarray(g)
        applied_sum += np.asarray(deq)
    # the residual bounds the gap between applied and true cumulative signal
    gap = np.abs(true_sum - applied_sum)
    assert gap.max() <= float(jnp.abs(residual).max()) + 1e-5


def test_wire_bytes_ratio():
    grads = {"a": jnp.zeros((10, 10)), "b": jnp.zeros((7,))}
    w = wire_bytes_saved(grads)
    assert w["ratio"] == 4.0
    assert w["fp32_bytes"] == 4 * 107

import os

# Smoke tests and benches must see the single real CPU device; ONLY the
# dry-run sets xla_force_host_platform_device_count (in its first lines).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

"""MovementLedger + model-coverage pass: the unified byte-attribution
walker behind the four `stencil.distributed.count_*` counters.

Fast tier (1-device, runs under `-m "not slow"`):
  * the ledger's category split recomposes the legacy counters
    BYTE-IDENTICALLY on Pallas programs (fused / batched+guarded) — the
    refactor's contract: `count_pallas_hbm_bytes` == pallas_hbm +
    guard_field_reads, `count_guard_bytes` == guard_field_reads +
    guard_flag_words;
  * collective categories (psum / all_gather / host_transfer) are
    attributed, and `total()` rejects unknown category names;
  * `check_model_coverage` passes on exact claims and FAILS on each
    defect class: an unclaimed nonzero category, a claim the count
    contradicts, a claim on an unpriced category, an unknown claim name;
  * the backward-compat re-exports (`_iter_jaxprs`,
    `_count_ppermute_bytes` in `stencil.distributed`) still resolve.

Slow tier (4-device subprocess): ledger totals == all four legacy
counters on real distributed programs (collective / remote_dma /
verified / fused local kernel).
"""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_ok
from repro.analysis import (CATEGORIES, ModelCoverageError, MovementLedger,
                            audit_movement, check_model_coverage)
from repro.analysis.passes import available, get_pass
from repro.core import roofline as R
from repro.kernels.advection.advection import (advect_fused,
                                               advect_fused_batched,
                                               hbm_bytes_model)
from repro.kernels.advection.ref import AdvectParams, default_params
from repro.stencil import distributed as D

X, Y, Z, T = 8, 16, 128, 2


def _fields(shape, n=3, salt=0):
    key = jax.random.PRNGKey(11)
    return tuple(jax.random.normal(jax.random.fold_in(key, salt + i),
                                   shape, jnp.float32) * 0.01
                 for i in range(n))


@pytest.fixture(scope="module")
def fused_case():
    p = default_params(Z)
    F = _fields((X, Y, Z))
    return (lambda u, v, w: advect_fused(u, v, w, p, T=T,
                                         interpret=True)), F


@pytest.fixture(scope="module")
def guarded_batched_case():
    B = 2
    p = default_params(Z)
    pb = AdvectParams(*[jnp.stack([leaf] * B) for leaf in p])
    BF = tuple(jnp.stack([f] * B) for f in _fields((X, Y, Z)))
    return (lambda u, v, w: advect_fused_batched(
        u, v, w, pb, T=T, interpret=True, guard=True)), BF, B


def test_ledger_recomposes_legacy_counters(fused_case, guarded_batched_case):
    for fn, args in (fused_case, guarded_batched_case[:2]):
        led = MovementLedger.of(fn, *args)
        assert (D.count_pallas_hbm_bytes(fn, *args)
                == led.total("pallas_hbm", "guard_field_reads"))
        assert (D.count_guard_bytes(fn, *args)
                == led.total("guard_field_reads", "guard_flag_words"))
        assert D.count_exchange_wire_bytes(fn, *args) \
            == led.total("ppermute_wire") == 0
        assert D.count_integrity_bytes(fn, *args) \
            == led.total("integrity_words") == 0


def test_ledger_fused_totals_match_model(fused_case):
    fn, args = fused_case
    led = MovementLedger.of(fn, *args)
    assert led.total("pallas_hbm") == hbm_bytes_model(X, Y, Z, 4, "fused",
                                                      T=T)
    assert led.total("guard_field_reads") == 0
    # every record is attributed to a known category
    assert set(led.totals()) == set(CATEGORIES)
    assert led.grand_total() == sum(led.totals().values())


def test_ledger_guard_split(guarded_batched_case):
    fn, args, B = guarded_batched_case
    led = MovementLedger.of(fn, *args)
    parts = R.guard_bytes_model_parts(X, Y, Z, batch=B)
    assert led.total("guard_field_reads") == parts["field_reads"]
    assert led.total("guard_flag_words") == parts["flag_words"]
    assert led.total("pallas_hbm") == B * hbm_bytes_model(X, Y, Z, 4,
                                                          "fused", T=T)


def test_ledger_rejects_unknown_category(fused_case):
    fn, args = fused_case
    led = MovementLedger.of(fn, *args)
    with pytest.raises(KeyError, match="hbm_wire"):
        led.total("hbm_wire")


def test_ledger_collective_and_host_categories():
    def prog(x):
        y = jax.device_put(x)
        return jnp.sum(y) + jnp.sum(x * 2.0)

    led = MovementLedger.of(prog, jnp.ones((4, 8, 16), jnp.float32))
    assert led.total("host_transfer") == 4 * 8 * 16 * 4
    assert led.total("psum") == 0       # no pmapped psum in this program


def test_audit_movement_matches_ledger(fused_case):
    fn, args = fused_case
    led = MovementLedger.of(fn, *args)
    assert audit_movement(fn, *args).totals() == led.totals()


def test_coverage_pass_and_failure_modes(fused_case):
    fn, args = fused_case
    led = MovementLedger.of(fn, *args)
    good = {"pallas_hbm": led.total("pallas_hbm")}
    report = check_model_coverage(led, good)
    assert report.ok and not report.failures
    report.raise_if_failed()            # no-op when green

    # (1) unclaimed nonzero category
    bad = check_model_coverage(led, {})
    assert not bad.ok
    assert any("pallas_hbm" in str(f) for f in bad.failures)
    with pytest.raises(ModelCoverageError, match="pallas_hbm"):
        bad.raise_if_failed()
    # (2) a claim the count contradicts
    bad = check_model_coverage(led, {"pallas_hbm": 1})
    assert not bad.ok and any("pallas_hbm" in str(f) for f in bad.failures)
    # (3) claiming the documented-unpriced category is itself a failure
    bad = check_model_coverage(
        led, dict(good, pallas_control=led.total("pallas_control")))
    assert not bad.ok and any("unpriced" in str(f).lower()
                              for f in bad.failures)
    # (4) unknown claim name
    bad = check_model_coverage(led, dict(good, wire_hbm=1))
    assert not bad.ok and any("wire_hbm" in str(f) for f in bad.failures)


def test_pass_registry_surfaces_the_four_passes(fused_case):
    names = [n for n, _ in available()]
    for want in ("movement-ledger", "model-coverage", "retrace",
                 "vmem-budget", "tiling-contract"):
        assert want in names
    fn, args = fused_case
    led = get_pass("movement-ledger").run(fn, *args)
    rep = get_pass("model-coverage").run(
        fn, *args, claims={"pallas_hbm": led.total("pallas_hbm")})
    assert rep.ok
    with pytest.raises(KeyError, match="registered"):
        get_pass("nonexistent-pass")


def test_distributed_backward_compat_reexports(fused_case):
    # the refactor keeps the legacy private names importable: downstream
    # code (and the old tests) reach them through stencil.distributed
    fn, args = fused_case
    jaxpr = jax.make_jaxpr(fn)(*args)
    assert list(D._iter_jaxprs(jaxpr))
    assert D._count_ppermute_bytes(fn, args, keep=lambda v: True) == 0


# --- slow tier: 4-device subprocess -----------------------------------------

LEDGER_EQUIV_CODE = textwrap.dedent("""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from repro.analysis import MovementLedger
    from repro.launch.mesh import make_stencil_mesh
    from repro.kernels.advection.ref import default_params
    from repro.stencil import distributed as D

    p = default_params(12)
    mesh = make_stencil_mesh(2, 2)
    key = jax.random.PRNGKey(0)
    G = tuple(jax.random.normal(jax.random.fold_in(key, i),
                                (8, 8, 12), jnp.float32) * 0.01
              for i in range(3))
    kw = dict(axis="y", x_axis="x", T=2)
    cases = [
        D.make_distributed_step(mesh, p, **kw),
        D.make_distributed_step(mesh, p, exchange="remote_dma", **kw),
        D.make_distributed_step(mesh, p, verify_integrity=True, **kw),
        D.make_distributed_step(mesh, p, local_kernel="fused", **kw),
        D.make_distributed_run(mesh, p, n_blocks=3, local_kernel="fused",
                               **kw),
    ]
    for i, fn in enumerate(cases):
        led = MovementLedger.of(fn, *G)
        assert D.count_exchange_wire_bytes(fn, *G) \\
            == led.total("ppermute_wire"), i
        assert D.count_integrity_bytes(fn, *G) \\
            == led.total("integrity_words"), i
        assert D.count_pallas_hbm_bytes(fn, *G) \\
            == led.total("pallas_hbm", "guard_field_reads"), i
        assert D.count_guard_bytes(fn, *G) \\
            == led.total("guard_field_reads", "guard_flag_words"), i
        assert led.total("ppermute_wire") > 0, i
    print("OK")
""")


@pytest.mark.slow
def test_ledger_equals_legacy_counters_multidevice():
    run_ok(LEDGER_EQUIV_CODE, timeout=600)

"""v4 `fused` kernel: interpret-mode equivalence vs the multi-step f64
oracle, Y-tiling equivalence (including non-multiple tile sizes), and the
VMEM-budget contract of the Y-tiled shift register."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.advection.advection import (advect_dataflow, advect_fused,
                                               fused_register_bytes,
                                               hbm_bytes_model)
from repro.kernels.advection.ref import (default_params, pw_multistep_ref_f64,
                                         pw_step_ref)

DT = 0.01


def fields(shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    return tuple(jnp.asarray(rng.normal(size=shape), dtype) for _ in range(3))


def max_err(out, oracle):
    return max(float(np.max(np.abs(np.asarray(a, np.float64) - b)))
               for a, b in zip(out, oracle))


@pytest.mark.parametrize("T", [1, 2, 4])
def test_fused_matches_multistep_f64_oracle(T):
    shape = (6, 10, 12)
    u, v, w = fields(shape)
    p = default_params(shape[2])
    oracle = pw_multistep_ref_f64(u, v, w, p, T, DT)
    out = advect_fused(u, v, w, p, T=T, dt=DT)
    assert max_err(out, oracle) < 1e-4, T


def test_fused_t1_equals_one_euler_step():
    """T=1 degenerates to dataflow + Euler update (same f32 arithmetic)."""
    shape = (5, 8, 8)
    u, v, w = fields(shape)
    p = default_params(shape[2])
    su, sv, sw = advect_dataflow(u, v, w, p)
    expect = (u + DT * su, v + DT * sv, w + DT * sw)
    out = advect_fused(u, v, w, p, T=1, dt=DT)
    assert max_err(out, [np.asarray(e, np.float64) for e in expect]) < 1e-6


@pytest.mark.parametrize("tiling", ["grid", "host"])
def test_fused_ytiled_matches_untiled_nonmultiple_tiles(tiling):
    """y_tile that does NOT divide Y (17 = 3*5 + 2) and degenerate tiles
    still restitch to the exact untiled result, on both the in-grid and the
    retained host-tiled path."""
    shape = (5, 17, 12)
    T = 2
    u, v, w = fields(shape, seed=3)
    p = default_params(shape[2])
    full = advect_fused(u, v, w, p, T=T, dt=DT)
    for y_tile in (5, 7, 64):
        tiled = advect_fused(u, v, w, p, T=T, dt=DT, y_tile=y_tile,
                             tiling=tiling)
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(full, tiled))
        assert err == 0.0, (tiling, y_tile, err)


def test_fused_boundary_cells_frozen():
    """Zero-source boundaries: edge cells keep their initial values for all
    T substeps (the oracle's contract, streamed through the ring)."""
    shape = (6, 9, 10)
    u, v, w = fields(shape, seed=1)
    out = advect_fused(u, v, w, default_params(shape[2]), T=3, dt=DT)
    for f0, fT in zip((u, v, w), out):
        np.testing.assert_array_equal(np.asarray(fT[0]), np.asarray(f0[0]))
        np.testing.assert_array_equal(np.asarray(fT[-1]), np.asarray(f0[-1]))
        np.testing.assert_array_equal(np.asarray(fT[:, 0]),
                                      np.asarray(f0[:, 0]))
        np.testing.assert_array_equal(np.asarray(fT[:, :, -1]),
                                      np.asarray(f0[:, :, -1]))


def test_fused_rejects_bad_T():
    u, v, w = fields((4, 8, 8))
    with pytest.raises(ValueError):
        advect_fused(u, v, w, default_params(8), T=0)


def test_ops_wrapper_fused():
    from repro.kernels.advection.ops import pw_advect, pw_advect_fused
    shape = (5, 8, 8)
    u, v, w = fields(shape, seed=2)
    p = default_params(shape[2])
    oracle = pw_multistep_ref_f64(u, v, w, p, 2, DT)
    out = pw_advect_fused(u, v, w, p, T=2, dt=DT)
    assert max_err(out, oracle) < 1e-4
    with pytest.raises(ValueError):
        pw_advect(u, v, w, p, variant="fused")


def test_domain_fused_step_and_advance():
    from repro.stencil.advection import AdvectionDomain
    dom = AdvectionDomain(5, 8, 8, variant="fused", fuse_T=2, dt=DT)
    u, v, w = dom.init()
    p = dom.params
    out = dom.step(u, v, w)
    ru, rv, rw = u, v, w
    for _ in range(2):
        ru, rv, rw = pw_step_ref(ru, rv, rw, p, DT)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(out, (ru, rv, rw)))
    assert err < 1e-4
    assert dom.substeps_per_step() == 2
    out4 = dom.advance(u, v, w, 4)
    assert out4[0].shape == u.shape
    with pytest.raises(ValueError):
        dom.advance(u, v, w, 3)   # not a multiple of fuse_T
    with pytest.raises(ValueError):
        dom.step(u, v, w, dt=0.5)  # fused bakes dt into the kernel
    with pytest.raises(ValueError):
        dom.sources(u, v, w)


# --- x_interior_mask: the 2D-decomposition hook ----------------------------

def test_fused_x_interior_mask_matches_masked_reference_loop():
    """The kernel's per-slice x mask reproduces the 2D distributed halo
    semantics: masked planes are frozen walls, exactly like the y row mask;
    grid tiling does not change a bit of it; all-ones is a bitwise no-op."""
    from repro.kernels.advection.ref import pw_advect_ref
    X, Y, Z, T = 8, 12, 10, 3
    u, v, w = fields((X, Y, Z), seed=8)
    p = default_params(Z)
    base = advect_fused(u, v, w, p, T=T, dt=DT)
    ones = advect_fused(u, v, w, p, T=T, dt=DT,
                        x_interior_mask=jnp.ones((X,)))
    for a, b in zip(base, ones):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    xm = np.ones((X,), np.float32)
    xm[:3] = 0.0                     # e.g. wrapped x-halo planes of a shard
    m = jnp.asarray(xm)[:, None, None] > 0
    us, vs, ws = u, v, w
    for _ in range(T):
        su, sv, sw = pw_advect_ref(us, vs, ws, p)
        us = us + DT * jnp.where(m, su, 0.0)
        vs = vs + DT * jnp.where(m, sv, 0.0)
        ws = ws + DT * jnp.where(m, sw, 0.0)
    out = advect_fused(u, v, w, p, T=T, dt=DT, x_interior_mask=jnp.asarray(xm))
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(out, (us, vs, ws)))
    assert err < 1e-6, err
    tiled = advect_fused(u, v, w, p, T=T, dt=DT, y_tile=4,
                         x_interior_mask=jnp.asarray(xm))
    for a, b in zip(tiled, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_x_interior_mask_contract_checks():
    X, Y, Z = 6, 18, 8
    u, v, w = fields((X, Y, Z), seed=9)
    p = default_params(Z)
    with pytest.raises(ValueError):   # shape must match X
        advect_fused(u, v, w, p, T=2, x_interior_mask=jnp.ones((X + 1,)))
    with pytest.raises(ValueError):   # host tiling cannot thread the mask
        advect_fused(u, v, w, p, T=2, y_tile=6, tiling="host",
                     x_interior_mask=jnp.ones((X,)))


# --- VMEM budget: the Y-tiled register is bounded irrespective of Y --------

VMEM_BUDGET_BYTES = 8 * 1024 * 1024   # half a v5e's 16 MiB VMEM, for head-
                                      # room against double-buffered slices


@pytest.mark.parametrize("Y", [1024, 4096, 65536])
@pytest.mark.parametrize("T", [1, 2, 4, 8])
def test_ytiled_register_stays_under_vmem_budget(Y, T):
    """Fig. 8 contract: at fixed (y_tile, Z) the register size is constant
    in Y — the paper's 67M/268M grids fit the same VMEM as the 1M grid."""
    Z, item, y_tile = 64, 4, 128
    b = fused_register_bytes(T, Y, Z, item, y_tile=y_tile)
    assert b == fused_register_bytes(T, 1024, Z, item, y_tile=y_tile)
    assert b <= VMEM_BUDGET_BYTES, (Y, T, b)
    # untiled at Y=65536 would blow the budget for T>=2 — tiling is load-
    # bearing, not decorative
    if T >= 2:
        assert fused_register_bytes(T, 65536, Z, item) > VMEM_BUDGET_BYTES


def test_domain_vmem_accounting():
    from repro.stencil.advection import AdvectionDomain
    dom = AdvectionDomain(16, 65536, 64, variant="fused", fuse_T=4,
                          y_tile=128)
    assert dom.vmem_register_bytes() <= VMEM_BUDGET_BYTES
    assert dom.hbm_bytes_per_step() < hbm_bytes_model(
        16, 65536, 64, 4, "dataflow", T=4)


@pytest.mark.slow
@pytest.mark.parametrize("shape,T,y_tile", [
    ((12, 32, 128), 4, 8),
    ((8, 24, 40), 8, 6),
    ((5, 8, 256), 2, None),
])
def test_fused_large_shapes_slow(shape, T, y_tile):
    u, v, w = fields(shape, seed=4)
    p = default_params(shape[2])
    oracle = pw_multistep_ref_f64(u, v, w, p, T, DT)
    out = advect_fused(u, v, w, p, T=T, dt=DT, y_tile=y_tile)
    assert max_err(out, oracle) < 1e-4, (shape, T, y_tile)

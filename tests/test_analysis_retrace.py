"""Retrace detector: config knobs must not leak static Python values
into the trace (the PR 5 `dma_block_index` bug class).

Fast tier (1-device, runs under `-m "not slow"`):
  * the deliberately-broken static-parity fixture is flagged RED with a
    "leak" finding naming the first diverging equation, and its
    traced-parity fix is GREEN — the detector's acceptance pair;
  * expect="distinct" catches a silently-ignored knob ("inert");
  * `Perturbation` validates its inputs; `driver_fingerprint` is
    deterministic and literal-value-insensitive (a literal passed as an
    argument is cache-compatible, so it must not split fingerprints).

Slow tier (4-device subprocess): the real drivers —
`make_distributed_run` shares one trace across `n_blocks` and block
parities while `y_tile` genuinely changes it, and
`make_distributed_step(exchange="remote_dma")` shares one trace across
`dma_block_index` values (the regression that motivated the pass).
"""
import textwrap

import jax.numpy as jnp
import pytest

from _subproc import run_ok
from repro.analysis import (Perturbation, detect_retrace,
                            driver_fingerprint, make_static_parity_driver,
                            make_traced_parity_driver)


def test_static_parity_fixture_flagged_red():
    report = detect_retrace(
        make_static_parity_driver,
        [Perturbation("block_index", (0, 1), expect="shared")])
    assert not report.ok
    assert len(report.findings) == 1
    f = report.findings[0]
    assert f.kind == "leak" and f.knob == "block_index"
    assert "divergence" in f.detail or "differ" in f.detail
    with pytest.raises(AssertionError, match="block_index"):
        report.raise_if_failed()
    # the two parities fingerprint differently — that IS the bug
    assert (report.fingerprints[("block_index", 0)]
            != report.fingerprints[("block_index", 1)])


def test_traced_parity_fixture_green():
    report = detect_retrace(
        make_traced_parity_driver,
        [Perturbation("block_index", (0, 1, 2, 3), expect="shared")])
    assert report.ok and not report.findings
    report.raise_if_failed()            # no-op when green
    fps = {report.fingerprints[("block_index", k)] for k in range(4)}
    assert len(fps) == 1


def test_inert_knob_detected():
    # a factory that IGNORES its knob entirely: expect="distinct" must
    # flag the config as silently dead
    def factory(y_tile=2):
        del y_tile
        return (lambda u: u * 2.0), (jnp.zeros((4, 6, 8), jnp.float32),)

    report = detect_retrace(
        factory, [Perturbation("y_tile", (2, 4), expect="distinct")])
    assert not report.ok
    assert report.findings[0].kind == "inert"
    # and the same factory passes under expect="shared"
    assert detect_retrace(
        factory, [Perturbation("y_tile", (2, 4), expect="shared")]).ok


def test_perturbation_validation():
    with pytest.raises(ValueError, match="shared"):
        Perturbation("k", (1, 2), expect="same")
    with pytest.raises(ValueError, match=">= 2"):
        Perturbation("k", (1,))


def test_driver_fingerprint_deterministic_and_literal_insensitive():
    x = jnp.ones((4, 6, 8), jnp.float32)
    fn = lambda u: u * 2.0 + 1.0
    assert driver_fingerprint(fn, x) == driver_fingerprint(fn, x)
    # a different SHAPE is a different trace
    assert (driver_fingerprint(fn, x)
            != driver_fingerprint(fn, jnp.ones((4, 6, 16), jnp.float32)))
    # different literal VALUES are cache-compatible: scaling by 2 vs 3
    # shares the program structure, so the fingerprints must agree
    assert (driver_fingerprint(lambda u: u * 2.0, x)
            == driver_fingerprint(lambda u: u * 3.0, x))


# --- slow tier: the real distributed drivers --------------------------------

RETRACE_DRIVERS_CODE = textwrap.dedent("""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from repro.analysis import Perturbation, detect_retrace
    from repro.launch.mesh import make_stencil_mesh
    from repro.kernels.advection.ref import default_params
    from repro.stencil import distributed as D

    p = default_params(12)
    mesh = make_stencil_mesh(2, 2)
    key = jax.random.PRNGKey(0)
    G = tuple(jax.random.normal(jax.random.fold_in(key, i),
                                (8, 8, 12), jnp.float32) * 0.01
              for i in range(3))

    def run_factory(n_blocks=2, y_tile=None):
        fn = D.make_distributed_run(mesh, p, n_blocks=n_blocks, axis="y",
                                    x_axis="x", T=2, local_kernel="fused",
                                    y_tile=y_tile)
        return fn, G

    report = detect_retrace(run_factory, [
        Perturbation("n_blocks", (2, 3), expect="shared"),
        Perturbation("y_tile", (2, 4), expect="distinct"),
    ])
    report.raise_if_failed()

    def step_factory(dma_block_index=0):
        fn = D.make_distributed_step(mesh, p, axis="y", x_axis="x", T=2,
                                     exchange="remote_dma",
                                     dma_block_index=dma_block_index)
        return fn, G

    report = detect_retrace(step_factory, [
        Perturbation("dma_block_index", (0, 1), expect="shared"),
    ])
    report.raise_if_failed()
    print("OK")
""")


@pytest.mark.slow
def test_real_drivers_retrace_free_multidevice():
    run_ok(RETRACE_DRIVERS_CODE, timeout=600)

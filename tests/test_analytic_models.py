"""Analytic-model coverage: `hbm_bytes_model` across all variants x Z
alignment x fusion T, `pipeline_model` invariants, and the fusion-aware
roofline arithmetic-intensity model."""
import pytest

from _prop import given, settings, st
from repro.core import roofline as R
from repro.core.dataflow import pipeline_model
from repro.kernels.advection.advection import (fused_register_bytes,
                                               hbm_bytes_model)

VARIANTS = ("pointwise", "blocked", "dataflow", "wide", "fused")


# --- hbm_bytes_model -------------------------------------------------------

@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("Z", [128, 64, 100])   # aligned / pow2-misaligned /
@pytest.mark.parametrize("T", [1, 2, 4, 8])     # ragged-misaligned
def test_hbm_bytes_model_positive_and_monotone_in_T(variant, Z, T):
    X, Y = 64, 128
    b = hbm_bytes_model(X, Y, Z, 4, variant, T=T)
    assert b > 0
    if T > 1:
        assert b >= hbm_bytes_model(X, Y, Z, 4, variant, T=T - 1)


@pytest.mark.parametrize("Z,aligned", [(128, True), (256, True),
                                       (64, False), (100, False)])
def test_lane_efficiency_penalty(Z, aligned):
    """Misaligned Z is charged the lane-efficiency penalty on every variant
    except `wide` (whose alignment is a checked layout contract)."""
    X, Y = 32, 64
    for variant in ("pointwise", "blocked", "dataflow", "fused"):
        b = hbm_bytes_model(X, Y, Z, 4, variant)
        ideal = hbm_bytes_model(X, Y, 128, 4, variant) * (Z / 128)
        if aligned:
            assert b == pytest.approx(ideal, rel=1e-6), variant
        else:
            assert b > ideal, variant
    assert hbm_bytes_model(X, Y, 128, 4, "wide") > 0


@pytest.mark.parametrize("Z", [64, 128])
def test_ladder_strictly_reduces_traffic(Z):
    X, Y, T = 512, 512, 4
    b = {v: hbm_bytes_model(X, Y, Z, 4, v, T=T) for v in VARIANTS}
    assert b["pointwise"] > b["blocked"] > b["dataflow"] >= b["wide"] \
        > b["fused"]


@pytest.mark.parametrize("T", [2, 4, 8])
def test_fused_amortisation_acceptance(T):
    """Acceptance: fused(T) moves >= 0.75*T x less than dataflow for the
    same number of steps even with HOST-tiling halo overhead (so >= 3x from
    T=4, the headline criterion); the in-grid path amortises exactly T."""
    X, Y, Z = 512, 512, 64
    base = hbm_bytes_model(X, Y, Z, 4, "dataflow", T=T)
    fused_host = hbm_bytes_model(X, Y, Z, 4, "fused", T=T, y_tile=128,
                                 grid_tiled=False)
    ratio = base / fused_host
    assert ratio >= T * 0.75, (T, ratio)
    if T >= 4:
        assert ratio >= 3.0, (T, ratio)
    # untiled fused amortises exactly T (no halo overlap) — and so does the
    # in-grid tiled path, whose halo re-reads are a VMEM, not HBM, cost
    assert hbm_bytes_model(X, Y, Z, 4, "dataflow", T=T) \
        == hbm_bytes_model(X, Y, Z, 4, "fused", T=T) * T
    assert base == hbm_bytes_model(X, Y, Z, 4, "fused", T=T, y_tile=128,
                                   grid_tiled=True) * T


def test_y_tile_overhead_accounting():
    """HOST tiling adds exactly the halo rows, charged on BOTH sides (each
    block's kernel re-reads and re-writes its halo): 2*halo rows per
    interior tile boundary, halo=T for fused and 1 for the source
    variants. The in-grid path charges none of it."""
    X, Y, Z, T = 16, 256, 128, 4
    untiled = hbm_bytes_model(X, Y, Z, 4, "fused", T=T)
    tiled = hbm_bytes_model(X, Y, Z, 4, "fused", T=T, y_tile=64,
                            grid_tiled=False)
    n_tiles = 4
    halo_rows = 2 * T * (n_tiles - 1)
    assert tiled - untiled == 2 * 3 * X * halo_rows * Z * 4  # read + write
    d_untiled = hbm_bytes_model(X, Y, Z, 4, "dataflow")
    d_tiled = hbm_bytes_model(X, Y, Z, 4, "dataflow", y_tile=64,
                              grid_tiled=False)
    assert d_tiled - d_untiled == 2 * 3 * X * 2 * 1 * (n_tiles - 1) * Z * 4


def test_grid_tiled_charges_zero_hbm_halo_overlap():
    """The in-grid (y_tile, x) path: HBM bytes equal the untiled compulsory
    traffic for EVERY tile size — halo overlap relocates to the VMEM term —
    and are strictly below the host-tiled bytes whenever y_tile < Y."""
    from repro.kernels.advection.advection import vmem_halo_bytes_model
    X, Y, Z = 16, 256, 128
    for variant, T in (("blocked", 1), ("dataflow", 1), ("wide", 2),
                       ("fused", 4)):
        untiled = hbm_bytes_model(X, Y, Z, 4, variant, T=T)
        # wide's sweep keeps the sublane contract the model now enforces
        tiles = (32, 64, 96, 256) if variant == "wide" else (32, 64, 100, 256)
        for y_tile in tiles:
            grid = hbm_bytes_model(X, Y, Z, 4, variant, T=T, y_tile=y_tile,
                                   grid_tiled=True)
            assert grid == untiled, (variant, y_tile)
            vmem = vmem_halo_bytes_model(X, Y, Z, 4, variant, T=T,
                                         y_tile=y_tile)
            if y_tile < Y:
                if variant != "wide":   # wide has no host path to compare
                    host = hbm_bytes_model(X, Y, Z, 4, variant, T=T,
                                           y_tile=y_tile, grid_tiled=False)
                    assert grid < host, (variant, y_tile)
                assert vmem > 0, (variant, y_tile)
            else:
                assert vmem == 0, (variant, y_tile)
    # the relocated read-side halo bytes match the host model's read overlap
    n_tiles, halo = 4, 1
    vmem = vmem_halo_bytes_model(X, Y, Z, 4, "dataflow", y_tile=64)
    assert vmem == 3 * X * 2 * halo * (n_tiles - 1) * Z * 4


def test_fuse_update_accounting():
    """fuse_update=False charges the separate Euler-update pass (read field
    + read source + write field per field per step); fused kernels and
    fuse_update=True kernels do not pay it."""
    X, Y, Z, T = 16, 64, 128, 3
    for variant in ("blocked", "dataflow", "wide", "pointwise"):
        fused_upd = hbm_bytes_model(X, Y, Z, 4, variant, T=T)
        unfused = hbm_bytes_model(X, Y, Z, 4, variant, T=T,
                                  fuse_update=False)
        assert unfused - fused_upd == T * 3 * 3 * X * Y * Z * 4, variant
    # v4 fuses the update by construction: the flag is a no-op there
    assert hbm_bytes_model(X, Y, Z, 4, "fused", T=T, fuse_update=False) \
        == hbm_bytes_model(X, Y, Z, 4, "fused", T=T)


def test_hbm_bytes_model_rejects_unknown_variant():
    with pytest.raises(ValueError):
        hbm_bytes_model(8, 8, 8, 4, "nope")


def test_hbm_bytes_model_mirrors_wide_tiling_contract():
    """advect_wide refuses HOST y-tiling and non-sublane tiles, so the
    models must not price either; the in-grid path keeps the sublane
    contract per-tile and is priced."""
    from repro.kernels.advection.advection import vmem_halo_bytes_model
    with pytest.raises(ValueError):
        hbm_bytes_model(8, 64, 128, 4, "wide", y_tile=16, grid_tiled=False)
    with pytest.raises(ValueError):   # non-sublane tile: no execution path
        hbm_bytes_model(8, 64, 128, 4, "wide", y_tile=12)
    with pytest.raises(ValueError):
        vmem_halo_bytes_model(8, 64, 128, 4, "wide", y_tile=12)
    assert hbm_bytes_model(8, 64, 128, 4, "wide", y_tile=16) \
        == hbm_bytes_model(8, 64, 128, 4, "wide")
    # degenerate tile (>= Y) is the untiled path and stays legal either way
    assert hbm_bytes_model(8, 64, 128, 4, "wide", y_tile=64,
                           grid_tiled=False) \
        == hbm_bytes_model(8, 64, 128, 4, "wide")


def test_host_overlap_factor_matches_roofline_factor():
    """One geometry, two surfaces: hbm_bytes_model's host-tiled overlap and
    roofline.stencil_tiling_bytes_factor must agree exactly — this pins the
    two implementations together against drift."""
    X, Z = 8, 128
    for Y, y_tile in ((256, 64), (256, 100), (512, 128)):
        for variant, T in (("blocked", 2), ("dataflow", 3), ("fused", 4)):
            halo = T if variant == "fused" else 1
            host = hbm_bytes_model(X, Y, Z, 4, variant, T=T, y_tile=y_tile,
                                   grid_tiled=False)
            untiled = hbm_bytes_model(X, Y, Z, 4, variant, T=T)
            f = R.stencil_tiling_bytes_factor(Y, y_tile, halo,
                                              grid_tiled=False)
            assert host == pytest.approx(untiled * f), (variant, Y, y_tile)


def test_register_bytes_model():
    # 3 fields x 3T slices x rows x Z x itemsize
    assert fused_register_bytes(4, 1024, 64, 4) == 3 * 12 * 1024 * 64 * 4
    assert fused_register_bytes(4, 1024, 64, 4, y_tile=128) \
        == 3 * 12 * (128 + 8) * 64 * 4
    # tile larger than the grid clamps to the grid
    assert fused_register_bytes(2, 16, 8, 4, y_tile=64) \
        == fused_register_bytes(2, 16, 8, 4)


# --- halo_wire_bytes_model: the 2D-decomposition collective term ----------

def test_halo_wire_bytes_model_geometry():
    """x-then-y two-phase pricing: phase x moves raw-shard planes, phase y
    moves x-EXTENDED rows (the 2T extra columns are the corner blocks), an
    undecomposed axis moves nothing."""
    X, Y, Z, item, T = 64, 32, 16, 4, 3
    assert R.halo_wire_bytes_model(X, Y, Z, item, nx=1, ny=1, T=T) == 0
    y_only = R.halo_wire_bytes_model(X, Y, Z, item, nx=1, ny=4, T=T)
    assert y_only == 3 * item * 2 * T * X * Z          # rows are Xl == X wide
    x_only = R.halo_wire_bytes_model(X, Y, Z, item, nx=4, ny=1, T=T)
    assert x_only == 3 * item * 2 * T * Y * Z          # planes are Yl == Y
    both = R.halo_wire_bytes_model(X, Y, Z, item, nx=4, ny=4, T=T)
    Xl, Yl = X // 4, Y // 4
    assert both == 3 * item * (2 * T * Yl * Z          # phase x
                               + 2 * T * (Xl + 2 * T) * Z)   # phase y + corners
    corner_term = 3 * item * 2 * T * 2 * T * Z
    no_ext = 3 * item * (2 * T * Yl * Z + 2 * T * Xl * Z)
    assert both - no_ext == corner_term


def test_halo_wire_bytes_model_monotone_and_errors():
    X, Y, Z, item = 64, 32, 16, 4
    for T in (2, 3, 8):
        assert R.halo_wire_bytes_model(X, Y, Z, item, nx=2, ny=2, T=T) \
            > R.halo_wire_bytes_model(X, Y, Z, item, nx=2, ny=2, T=T - 1)
    with pytest.raises(ValueError):
        R.halo_wire_bytes_model(X, Y, Z, item, nx=3, ny=1)   # 64 % 3
    with pytest.raises(ValueError):
        R.halo_wire_bytes_model(X, Y, Z, item, nx=1, ny=5)
    with pytest.raises(ValueError):
        R.halo_wire_bytes_model(X, Y, Z, item, nx=0, ny=1)
    with pytest.raises(ValueError):
        R.halo_wire_bytes_model(X, Y, Z, item, T=0)


def test_halo_wire_bytes_feed_collective_term():
    """The modelled exchange bytes drive RooflineTerms.collective_s; deep
    meshes on small shards eventually go collective-bound — the regime the
    scaling2d sweep maps."""
    wire = R.halo_wire_bytes_model(4096, 1024, 64, 4, nx=16, ny=16, T=8)
    t = R.RooflineTerms(
        flops_per_dev=1e6, hbm_bytes_per_dev=1e3,
        ici_wire_bytes=wire, dcn_wire_bytes=0.0, n_chips=256)
    assert t.collective_s == pytest.approx(wire / R.ICI_BW)
    assert t.bound == "collective"


def test_domain_per_shard_accounting():
    from repro.stencil.advection import AdvectionDomain
    one = AdvectionDomain(4096, 1024, 64, variant="fused", fuse_T=4,
                          y_tile=128)
    assert one.halo_wire_bytes_per_step() == 0
    assert one.hbm_bytes_per_shard_step() == one.hbm_bytes_per_step()
    prev = one.hbm_bytes_per_shard_step()
    for nx, ny in ((2, 1), (2, 2), (4, 4), (16, 16)):
        dom = AdvectionDomain(4096, 1024, 64, variant="fused", fuse_T=4,
                              y_tile=128, mesh_nx=nx, mesh_ny=ny)
        b = dom.hbm_bytes_per_shard_step()
        assert b < prev, (nx, ny)     # strong scaling: per-shard pass falls
        prev = b
        assert dom.halo_wire_bytes_per_step() == R.halo_wire_bytes_model(
            4096, 1024, 64, 4, nx=nx, ny=ny, T=4)
        assert dom.shard_shape() == (4096 // nx, 1024 // ny)
    with pytest.raises(ValueError):
        AdvectionDomain(10, 8, 8, mesh_nx=3).shard_shape()
    with pytest.raises(ValueError):
        AdvectionDomain(10, 8, 8, mesh_ny=3).halo_wire_bytes_per_step()


# --- pipeline_model invariants --------------------------------------------

@settings(max_examples=100, deadline=None)
@given(stage_times=st.lists(st.floats(1e-4, 10.0), min_size=1, max_size=6),
       n=st.integers(1, 1000))
def test_pipeline_model_invariants(stage_times, n):
    stages = {f"s{i}": t for i, t in enumerate(stage_times)}
    m = pipeline_model(stages, n)
    # overlap never hurts
    assert m["pipelined_s"] <= m["serial_s"] + 1e-9
    assert m["speedup"] >= 1.0 - 1e-9
    # bottleneck is the max stage
    assert stages[m["bottleneck"]] == pytest.approx(max(stage_times))
    # a single stage cannot overlap with itself
    if len(stage_times) == 1:
        assert m["pipelined_s"] == pytest.approx(m["serial_s"])


def test_pipeline_model_single_stage_exact():
    m = pipeline_model({"compute": 2.0}, 10)
    assert m["serial_s"] == pytest.approx(20.0)
    assert m["pipelined_s"] == pytest.approx(20.0)
    assert m["speedup"] == pytest.approx(1.0)
    assert m["bottleneck"] == "compute"


# --- fusion-aware roofline -------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(fpc=st.floats(1.0, 500.0), bpc=st.floats(1.0, 200.0),
       T=st.integers(1, 64))
def test_stencil_ai_scales_linearly_in_T(fpc, bpc, T):
    ai1 = R.stencil_arithmetic_intensity(fpc, bpc)
    aiT = R.stencil_arithmetic_intensity(fpc, bpc, fusion_T=T)
    assert aiT == pytest.approx(T * ai1)


def test_stencil_ai_rejects_bad_T():
    with pytest.raises(ValueError):
        R.stencil_arithmetic_intensity(53.0, 8.0, fusion_T=0)


def test_stencil_tiling_bytes_factor():
    """In-grid tiling keeps AI at the compulsory-traffic value; host tiling
    deflates it by exactly the halo restaging factor."""
    Y, y_tile, halo = 256, 64, 4
    assert R.stencil_tiling_bytes_factor(Y, y_tile, halo) == 1.0
    assert R.stencil_tiling_bytes_factor(Y, None, halo, grid_tiled=False) \
        == 1.0
    f = R.stencil_tiling_bytes_factor(Y, y_tile, halo, grid_tiled=False)
    assert f == pytest.approx((Y + 2 * halo * 3) / Y)
    ai = R.stencil_arithmetic_intensity(53.0, 32.0, fusion_T=4)
    ai_host = R.stencil_arithmetic_intensity(53.0, 32.0, fusion_T=4,
                                             tiling_bytes_factor=f)
    assert ai_host == pytest.approx(ai / f)
    # a deflated AI can only push the required fusion depth up
    assert R.stencil_ridge_T(53.0, 32.0, tiling_bytes_factor=f) \
        >= R.stencil_ridge_T(53.0, 32.0)
    with pytest.raises(ValueError):
        R.stencil_arithmetic_intensity(53.0, 32.0, tiling_bytes_factor=0.5)


def test_stencil_ridge_T_crosses_ridge():
    """At the returned T the fused AI meets/exceeds the machine ridge; at
    T-1 it does not (for a genuinely memory-bound stencil)."""
    fpc, bpc = 53.0, 8.0 * 4   # PW stencil, 8 f32 values/cell per pass
    Tr = R.stencil_ridge_T(fpc, bpc)
    ridge = R.PEAK_FLOPS / R.HBM_BW
    assert R.stencil_arithmetic_intensity(fpc, bpc, fusion_T=Tr) \
        >= ridge - 1e-9
    assert Tr > 1
    assert R.stencil_arithmetic_intensity(fpc, bpc, fusion_T=Tr - 1) < ridge


@given(X=st.integers(1, 16), Y=st.integers(1, 32), Z=st.integers(1, 256),
       batch=st.integers(1, 8), n_fields=st.integers(1, 6))
@settings(max_examples=40, deadline=None)
def test_guard_parts_sum_to_guard_bytes_model(X, Y, Z, batch, n_fields):
    """The two-category split the analysis ledger claims
    (`guard_field_reads` / `guard_flag_words`) recomposes
    `guard_bytes_model` exactly, for every geometry."""
    parts = R.guard_bytes_model_parts(X, Y, Z, batch=batch,
                                      n_fields=n_fields)
    assert set(parts) == {"field_reads", "flag_words"}
    assert sum(parts.values()) == R.guard_bytes_model(X, Y, Z, batch=batch,
                                                      n_fields=n_fields)
    assert parts["flag_words"] == batch * X * R.GUARD_FLAG_ITEMSIZE

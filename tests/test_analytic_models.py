"""Analytic-model coverage: `hbm_bytes_model` across all variants x Z
alignment x fusion T, `pipeline_model` invariants, and the fusion-aware
roofline arithmetic-intensity model."""
import pytest

from _prop import given, settings, st
from repro.core import roofline as R
from repro.core.dataflow import pipeline_model
from repro.kernels.advection.advection import (fused_register_bytes,
                                               hbm_bytes_model)

VARIANTS = ("pointwise", "blocked", "dataflow", "wide", "fused")


# --- hbm_bytes_model -------------------------------------------------------

@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("Z", [128, 64, 100])   # aligned / pow2-misaligned /
@pytest.mark.parametrize("T", [1, 2, 4, 8])     # ragged-misaligned
def test_hbm_bytes_model_positive_and_monotone_in_T(variant, Z, T):
    X, Y = 64, 128
    b = hbm_bytes_model(X, Y, Z, 4, variant, T=T)
    assert b > 0
    if T > 1:
        assert b >= hbm_bytes_model(X, Y, Z, 4, variant, T=T - 1)


@pytest.mark.parametrize("Z,aligned", [(128, True), (256, True),
                                       (64, False), (100, False)])
def test_lane_efficiency_penalty(Z, aligned):
    """Misaligned Z is charged the lane-efficiency penalty on every variant
    except `wide` (whose alignment is a checked layout contract)."""
    X, Y = 32, 64
    for variant in ("pointwise", "blocked", "dataflow", "fused"):
        b = hbm_bytes_model(X, Y, Z, 4, variant)
        ideal = hbm_bytes_model(X, Y, 128, 4, variant) * (Z / 128)
        if aligned:
            assert b == pytest.approx(ideal, rel=1e-6), variant
        else:
            assert b > ideal, variant
    assert hbm_bytes_model(X, Y, 128, 4, "wide") > 0


@pytest.mark.parametrize("Z", [64, 128])
def test_ladder_strictly_reduces_traffic(Z):
    X, Y, T = 512, 512, 4
    b = {v: hbm_bytes_model(X, Y, Z, 4, v, T=T) for v in VARIANTS}
    assert b["pointwise"] > b["blocked"] > b["dataflow"] >= b["wide"] \
        > b["fused"]


@pytest.mark.parametrize("T", [2, 4, 8])
def test_fused_amortisation_acceptance(T):
    """Acceptance: fused(T) moves >= 0.75*T x less than dataflow for the
    same number of steps even with Y-tiling halo overhead (so >= 3x from
    T=4, the headline criterion)."""
    X, Y, Z = 512, 512, 64
    base = hbm_bytes_model(X, Y, Z, 4, "dataflow", T=T)
    fused = hbm_bytes_model(X, Y, Z, 4, "fused", T=T, y_tile=128)
    ratio = base / fused
    assert ratio >= T * 0.75, (T, ratio)
    if T >= 4:
        assert ratio >= 3.0, (T, ratio)
    # untiled fused amortises exactly T (no halo overlap)
    assert hbm_bytes_model(X, Y, Z, 4, "dataflow", T=T) \
        == hbm_bytes_model(X, Y, Z, 4, "fused", T=T) * T


def test_y_tile_overhead_accounting():
    """Tiling adds exactly the halo rows, charged on BOTH sides (each tile's
    kernel re-reads and re-writes its halo): 2*halo rows per interior tile
    boundary, halo=T for fused and 1 for the source variants."""
    X, Y, Z, T = 16, 256, 128, 4
    untiled = hbm_bytes_model(X, Y, Z, 4, "fused", T=T)
    tiled = hbm_bytes_model(X, Y, Z, 4, "fused", T=T, y_tile=64)
    n_tiles = 4
    halo_rows = 2 * T * (n_tiles - 1)
    assert tiled - untiled == 2 * 3 * X * halo_rows * Z * 4  # read + write
    d_untiled = hbm_bytes_model(X, Y, Z, 4, "dataflow")
    d_tiled = hbm_bytes_model(X, Y, Z, 4, "dataflow", y_tile=64)
    assert d_tiled - d_untiled == 2 * 3 * X * 2 * 1 * (n_tiles - 1) * Z * 4


def test_hbm_bytes_model_rejects_unknown_variant():
    with pytest.raises(ValueError):
        hbm_bytes_model(8, 8, 8, 4, "nope")


def test_hbm_bytes_model_mirrors_wide_tiling_contract():
    """advect_wide refuses y_tile, so the model must not price it."""
    with pytest.raises(ValueError):
        hbm_bytes_model(8, 64, 128, 4, "wide", y_tile=16)
    # degenerate tile (>= Y) is the untiled path and stays legal
    assert hbm_bytes_model(8, 64, 128, 4, "wide", y_tile=64) \
        == hbm_bytes_model(8, 64, 128, 4, "wide")


def test_register_bytes_model():
    # 3 fields x 3T slices x rows x Z x itemsize
    assert fused_register_bytes(4, 1024, 64, 4) == 3 * 12 * 1024 * 64 * 4
    assert fused_register_bytes(4, 1024, 64, 4, y_tile=128) \
        == 3 * 12 * (128 + 8) * 64 * 4
    # tile larger than the grid clamps to the grid
    assert fused_register_bytes(2, 16, 8, 4, y_tile=64) \
        == fused_register_bytes(2, 16, 8, 4)


# --- pipeline_model invariants --------------------------------------------

@settings(max_examples=100, deadline=None)
@given(stage_times=st.lists(st.floats(1e-4, 10.0), min_size=1, max_size=6),
       n=st.integers(1, 1000))
def test_pipeline_model_invariants(stage_times, n):
    stages = {f"s{i}": t for i, t in enumerate(stage_times)}
    m = pipeline_model(stages, n)
    # overlap never hurts
    assert m["pipelined_s"] <= m["serial_s"] + 1e-9
    assert m["speedup"] >= 1.0 - 1e-9
    # bottleneck is the max stage
    assert stages[m["bottleneck"]] == pytest.approx(max(stage_times))
    # a single stage cannot overlap with itself
    if len(stage_times) == 1:
        assert m["pipelined_s"] == pytest.approx(m["serial_s"])


def test_pipeline_model_single_stage_exact():
    m = pipeline_model({"compute": 2.0}, 10)
    assert m["serial_s"] == pytest.approx(20.0)
    assert m["pipelined_s"] == pytest.approx(20.0)
    assert m["speedup"] == pytest.approx(1.0)
    assert m["bottleneck"] == "compute"


# --- fusion-aware roofline -------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(fpc=st.floats(1.0, 500.0), bpc=st.floats(1.0, 200.0),
       T=st.integers(1, 64))
def test_stencil_ai_scales_linearly_in_T(fpc, bpc, T):
    ai1 = R.stencil_arithmetic_intensity(fpc, bpc)
    aiT = R.stencil_arithmetic_intensity(fpc, bpc, fusion_T=T)
    assert aiT == pytest.approx(T * ai1)


def test_stencil_ai_rejects_bad_T():
    with pytest.raises(ValueError):
        R.stencil_arithmetic_intensity(53.0, 8.0, fusion_T=0)


def test_stencil_ridge_T_crosses_ridge():
    """At the returned T the fused AI meets/exceeds the machine ridge; at
    T-1 it does not (for a genuinely memory-bound stencil)."""
    fpc, bpc = 53.0, 8.0 * 4   # PW stencil, 8 f32 values/cell per pass
    Tr = R.stencil_ridge_T(fpc, bpc)
    ridge = R.PEAK_FLOPS / R.HBM_BW
    assert R.stencil_arithmetic_intensity(fpc, bpc, fusion_T=Tr) \
        >= ridge - 1e-9
    assert Tr > 1
    assert R.stencil_arithmetic_intensity(fpc, bpc, fusion_T=Tr - 1) < ridge

"""Benchmark trend gate: freshly generated ``BENCH_*.json`` artifacts vs
the committed baselines in ``benchmarks/baselines.json``.

The sweeps gate their own invariants (SystemExit inside each
``benchmarks/*.py``); THIS gate pins the key derived metrics across
commits, so a regression that each sweep individually tolerates (a
byte count that grew but still matches a loosened model, a replay bound
that crept up) fails CI against the recorded trend.

Baseline entries (per artifact file)::

    {"BENCH_recovery.json": [
        {"path": "integrity.0.counted_integrity_bytes",
         "direction": "eq", "value": 24, "rtol": 0.0},
        ...]}

``path`` is dot-separated into the artifact JSON (integer components
index lists). ``direction``:

  * ``eq`` — current == value exactly (invariants: byte counts, bitwise
    diffs, flag counts);
  * ``le`` — current <= value * (1 + rtol): the metric must not GROW
    past the baseline (overheads, replayed blocks);
  * ``ge`` — current >= value * (1 - rtol): the metric must not FALL
    below the baseline (throughputs, coverage counts).

Usage (from the repo root, after running the sweeps that produce the
artifacts — CI runs the ``--quick`` sweeps first)::

    python scripts/check_bench_trends.py            # gate
    python scripts/check_bench_trends.py --update   # rewrite baselines

All failures are explicit ``SystemExit`` raises (python -O safe).
"""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINES = os.path.join(ROOT, "benchmarks", "baselines.json")
DIRECTIONS = ("eq", "le", "ge")


def resolve(doc, path: str, artifact: str = "<artifact>"):
    """Walk a dot-separated path; integer components index lists. Every
    failure names the ARTIFACT the path was resolved against — a stale
    baseline path must point the operator at the sweep to re-run, not
    at this script."""
    node = doc
    for part in path.split("."):
        if isinstance(node, list):
            try:
                node = node[int(part)]
            except (ValueError, IndexError):
                raise SystemExit(
                    f"trend gate: {artifact}: path component {part!r} of "
                    f"{path!r} does not index the list (len {len(node)}) "
                    f"— rerun the sweep that writes {artifact}, or fix "
                    f"the baseline path") from None
        elif isinstance(node, dict):
            if part not in node:
                raise SystemExit(
                    f"trend gate: {artifact}: path component {part!r} of "
                    f"{path!r} missing; artifact keys: {sorted(node)[:12]}")
            node = node[part]
        else:
            raise SystemExit(
                f"trend gate: {artifact}: path {path!r} descends into a "
                f"leaf at {part!r}")
    if not isinstance(node, (int, float)) or isinstance(node, bool):
        raise SystemExit(
            f"trend gate: {artifact}: path {path!r} resolves to "
            f"{type(node).__name__}, not a number")
    return node


def entry_fields(artifact: str, e):
    """Validate one baseline entry's schema, naming the artifact on any
    gap (a hand-edited baselines.json must fail with the offending file,
    not a bare KeyError)."""
    if not isinstance(e, dict):
        raise SystemExit(f"trend gate: {artifact}: baseline entry is "
                         f"{type(e).__name__}, not an object: {e!r}")
    missing = [k for k in ("path", "value", "direction") if k not in e]
    if missing:
        raise SystemExit(
            f"trend gate: {artifact}: baseline entry missing key(s) "
            f"{missing}: {e!r}")
    return e["path"], e["value"], e["direction"]


def load_artifact(path: str, artifact: str):
    """Parse one BENCH artifact, converting a JSON syntax error into a
    named SystemExit (a truncated sweep run must not surface as a
    traceback)."""
    with open(path, encoding="utf-8") as f:
        try:
            return json.load(f)
        except json.JSONDecodeError as exc:
            raise SystemExit(
                f"trend gate: {artifact} is not valid JSON ({exc}) — the "
                f"sweep that writes it may have been interrupted; rerun "
                f"it") from None


def check(artifact: str, entries, doc) -> list:
    failures = []
    for e in entries:
        p, want, d = entry_fields(artifact, e)
        cur = resolve(doc, p, artifact)
        rtol = float(e.get("rtol", 0.0))
        if d not in DIRECTIONS:
            raise SystemExit(f"trend gate: bad direction {d!r} for "
                             f"{artifact}:{p}")
        ok = (cur == want if d == "eq" else
              cur <= want * (1.0 + rtol) if d == "le" else
              cur >= want * (1.0 - rtol))
        status = "ok" if ok else "REGRESSED"
        print(f"{artifact}:{p}: {cur} {d} {want} "
              f"(rtol={rtol}) {status}")
        if not ok:
            failures.append(f"{artifact}:{p} = {cur}, baseline "
                            f"{d} {want} (rtol={rtol})")
    return failures


def main(argv) -> None:
    update = "--update" in argv
    with open(BASELINES, encoding="utf-8") as f:
        baselines = json.load(f)
    if not baselines:
        raise SystemExit(f"trend gate: no baselines in {BASELINES}")
    failures = []
    for artifact, entries in sorted(baselines.items()):
        path = os.path.join(os.getcwd(), artifact)
        if not os.path.exists(path):
            raise SystemExit(
                f"trend gate: {artifact} not found in {os.getcwd()} — run "
                f"the sweep that produces it first (see benchmarks/)")
        doc = load_artifact(path, artifact)
        if update:
            for e in entries:
                p, _, _ = entry_fields(artifact, e)
                e["value"] = resolve(doc, p, artifact)
                print(f"{artifact}:{p} <- {e['value']}")
        else:
            failures.extend(check(artifact, entries, doc))
    if update:
        with open(BASELINES, "w", encoding="utf-8") as f:
            json.dump(baselines, f, indent=1)
            f.write("\n")
        print(f"baselines rewritten: {BASELINES}")
        return
    if failures:
        raise SystemExit("trend gate: benchmark regressions vs committed "
                         "baselines:\n  " + "\n  ".join(failures))
    print(f"trend gate: {sum(len(v) for v in baselines.values())} "
          f"baselines hold across {len(baselines)} artifacts")


if __name__ == "__main__":
    main(sys.argv[1:])

"""Docs checker: the documentation tier's executable contract.

1. Every fenced code block in README.md / docs/*.md tagged with a
   preceding ``<!-- docs-check -->`` marker is executed line-by-line as a
   shell command from the repo root — quoted commands that rot fail CI,
   so the quickstart can be trusted.
2. Every ``BENCH_*.json`` artifact in the tree must appear in the
   `benchmarks/README.md` schema tables — no unpriced, undocumented
   benchmark artifacts.

Run from anywhere: ``python scripts/check_docs.py``. All failures are
explicit ``SystemExit`` raises (python -O safe). CI runs this as the
`docs` job.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MARKER = "<!-- docs-check -->"
FENCE = re.compile(r"^```")


def tagged_blocks(path: str):
    """Yield (lineno, [command, ...]) for each docs-check-tagged fence."""
    with open(path, encoding="utf-8") as f:
        lines = f.read().splitlines()
    i = 0
    while i < len(lines):
        if lines[i].strip() == MARKER:
            j = i + 1
            while j < len(lines) and not FENCE.match(lines[j].strip()):
                if lines[j].strip():
                    raise SystemExit(
                        f"{path}:{i + 1}: {MARKER} must be immediately "
                        "followed by a fenced code block")
                j += 1
            if j >= len(lines):
                raise SystemExit(f"{path}:{i + 1}: {MARKER} with no fence")
            block, j = [], j + 1
            while j < len(lines) and not FENCE.match(lines[j].strip()):
                cmd = lines[j].strip()
                if cmd and not cmd.startswith("#"):
                    block.append(cmd)
                j += 1
            if j >= len(lines):
                raise SystemExit(
                    f"{path}:{i + 1}: docs-check fence never closed — "
                    "refusing to treat the rest of the file as commands")
            yield i + 1, block
            i = j
        i += 1


def run_tagged_commands() -> int:
    docs = [os.path.join(ROOT, "README.md")]
    docs_dir = os.path.join(ROOT, "docs")
    if os.path.isdir(docs_dir):
        docs += sorted(os.path.join(docs_dir, n)
                       for n in os.listdir(docs_dir) if n.endswith(".md"))
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")  # never probe libtpu in CI
    n = 0
    for path in docs:
        if not os.path.exists(path):
            raise SystemExit(f"documented file missing: {path}")
        for lineno, block in tagged_blocks(path):
            for cmd in block:
                rel = os.path.relpath(path, ROOT)
                print(f"[docs-check] {rel}:{lineno}$ {cmd}", flush=True)
                r = subprocess.run(cmd, shell=True, cwd=ROOT, env=env)
                if r.returncode != 0:
                    raise SystemExit(
                        f"{rel}:{lineno}: documented command failed "
                        f"(exit {r.returncode}): {cmd}")
                n += 1
    if n == 0:
        raise SystemExit("no docs-check-tagged commands found — the docs "
                         "tier must quote at least the tier-1 command")
    return n


def check_bench_index() -> int:
    with open(os.path.join(ROOT, "benchmarks", "README.md"),
              encoding="utf-8") as f:
        schema_doc = f.read()
    found = set()
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames
                       if d not in (".git", "__pycache__", ".github")]
        for name in filenames:
            if name.startswith("BENCH_") and name.endswith(".json"):
                found.add(name)
    if not found:
        raise SystemExit("no BENCH_*.json artifacts found in the tree")
    missing = sorted(n for n in found if n not in schema_doc)
    if missing:
        raise SystemExit(
            f"BENCH artifacts missing from benchmarks/README.md schema "
            f"tables: {missing}")
    return len(found)


def main() -> None:
    n_cmds = run_tagged_commands()
    n_bench = check_bench_index()
    print(f"docs-check OK: {n_cmds} documented commands executed, "
          f"{n_bench} BENCH artifacts indexed")


if __name__ == "__main__":
    main()

#!/usr/bin/env python
"""Static data-movement lint: every registered analysis pass driven over
the ladder's representative configs, written to ``BENCH_analysis.json``.

Nothing here executes a kernel — every rung is traced (`jax.make_jaxpr`
inside the passes) and audited statically, so the whole lint is a
build-time gate: it catches an unpriced byte category, a leaked static
config value, an over-budget VMEM ring or a broken Pallas tiling
contract before anything compiles.

Row families and their gates (every gate an explicit ``SystemExit`` —
``python -O`` safe):

  * ``ledger[]``   — `MovementLedger` totals per rung (fused /
    grid-tiled / distributed x {collective, remote_dma, fused local
    kernel} / verified / spec-driven verified / batched serving), each
    with the analytic claims (`hbm_bytes_model`,
    `halo_wire_bytes_model`, `integrity_bytes_model`,
    `guard_bytes_model_parts`) the model-coverage pass holds them to.
    GATE: `check_model_coverage` passes — every nonzero category is
    claimed EXACTLY and no claim is stale (`pallas_control` is the one
    documented unpriced category: scalar pipeline plumbing).
  * ``retrace[]``  — the retrace detector over `make_distributed_step`
    / `make_distributed_run` knobs (`dma_block_index` parity and
    `n_blocks` must NOT change the trace; `y_tile` MUST), plus the
    fixture pair: the deliberately-broken static-parity driver must be
    flagged (red) and its traced-parity fix must not (green). GATE:
    real drivers retrace-free, fixture flagged with a "leak" finding.
  * ``vmem[]``     — the static VMEM plans of each rung's rings/slabs
    vs `roofline.VMEM_PER_CORE`. GATE: every shipped config fits, and
    a deliberately oversized plan RAISES `VmemBudgetExceeded` naming
    its largest buffer.
  * ``tiling[]``   — `lint_tiling` over every Pallas-backed rung.
    GATE: zero errors (warnings — e.g. interpret-mode grids below the
    (8, 128) tile — are recorded, not fatal).

``--quick`` / ``BENCH_SMOKE=1`` skips the rungs marked slow; every
family keeps its quick rows FIRST so ``benchmarks/baselines.json``
paths resolve in both modes. ``--list`` prints the pass registry.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# Force 4 host devices BEFORE jax imports: the distributed rungs trace
# on a real 2x2 mesh.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp

from repro.analysis import (Perturbation, VmemBudgetExceeded, available,
                            get_pass, make_static_parity_driver,
                            make_traced_parity_driver)
from repro.analysis.vmem import (distributed_block_plan, fused_ring_plan,
                                 serving_ring_plan)
from repro.core import roofline as R
from repro.kernels.advection.advection import (advect_fused,
                                               advect_fused_batched,
                                               hbm_bytes_model)
from repro.kernels.advection.ref import AdvectParams, default_params
from repro.launch.mesh import make_stencil_mesh
from repro.stencil import spec as SP
from repro.stencil.distributed import (make_distributed_run,
                                       make_distributed_step)

GRID = (8, 16, 128)        # single-device rungs (lane-aligned Z)
DGRID = (8, 8, 128)        # distributed rungs on the 2x2 mesh
T = 2
ITEM = 4
BATCH = 2


def _fields(shape, n, salt=0):
    key = jax.random.PRNGKey(7)
    return tuple(jax.random.normal(jax.random.fold_in(key, salt + i),
                                   shape, jnp.float32) * 0.01
                 for i in range(n))


def _ledger_rungs(mesh, p, spec):
    """(name, slow, fn, args, claims) per rung. Claims are the analytic
    model terms the coverage pass holds the counted bytes to."""
    X, Y, Z = GRID
    DX, DY, DZ = DGRID
    nx = ny = 2
    Xl, Yl = DX // nx, DY // ny
    F = _fields(GRID, 3)
    G = _fields(DGRID, 3, salt=10)
    S = _fields(DGRID, spec.n_fields, salt=20)
    BF = tuple(jnp.stack([f] * BATCH) for f in F)
    pb = AdvectParams(*[jnp.stack([leaf] * BATCH) for leaf in p])
    sd = spec.halo(1)

    wire = R.halo_wire_bytes_model(DX, DY, DZ, ITEM, nx=nx, ny=ny, T=T)
    guard = R.guard_bytes_model_parts(X, Y, Z, batch=BATCH)
    rungs = [
        ("fused", False,
         lambda u, v, w: advect_fused(u, v, w, p, T=T, interpret=True),
         F, {"pallas_hbm": hbm_bytes_model(X, Y, Z, ITEM, "fused", T=T)}),
        ("grid_tiled", False,
         lambda u, v, w: advect_fused(u, v, w, p, T=T, interpret=True,
                                      y_tile=8),
         F, {"pallas_hbm": hbm_bytes_model(X, Y, Z, ITEM, "fused", T=T)}),
        ("dist_collective", False,
         make_distributed_step(mesh, p, axis="y", x_axis="x", T=T),
         G, {"ppermute_wire": wire}),
        ("dist_fused", False,
         make_distributed_step(mesh, p, axis="y", x_axis="x", T=T,
                               local_kernel="fused"),
         # the fused local kernel streams the HALO-EXTENDED slab
         G, {"ppermute_wire": wire,
             "pallas_hbm": hbm_bytes_model(Xl + 2 * T, Yl + 2 * T, DZ,
                                           ITEM, "fused", T=T)}),
        ("verified", False,
         make_distributed_step(mesh, p, axis="y", x_axis="x", T=T,
                               verify_integrity=True),
         G, {"ppermute_wire": wire,
             "integrity_words": R.integrity_bytes_model(
                 DX, DY, DZ, nx=nx, ny=ny, T=T)}),
        ("spec_verified", False,
         make_distributed_step(mesh, p, axis="y", x_axis="x", T=1,
                               spec=spec, spec_params=p,
                               local_kernel="fused", verify_integrity=True),
         S, {"ppermute_wire": R.halo_wire_bytes_model(
                 DX, DY, DZ, ITEM, nx=nx, ny=ny, T=1,
                 n_fields=spec.n_fields, depth=sd),
             "integrity_words": R.integrity_bytes_model(
                 DX, DY, DZ, nx=nx, ny=ny, T=1,
                 n_fields=spec.n_fields, depth=sd),
             "pallas_hbm": hbm_bytes_model(
                 Xl + 2 * sd, Yl + 2 * sd, DZ, ITEM, "fused", T=1,
                 n_fields=spec.n_fields, halo_depth=sd)}),
        ("serving_batched", False,
         lambda u, v, w: advect_fused_batched(u, v, w, pb, T=T,
                                              interpret=True, guard=True),
         BF, {"pallas_hbm": BATCH * hbm_bytes_model(X, Y, Z, ITEM,
                                                    "fused", T=T),
              "guard_field_reads": guard["field_reads"],
              "guard_flag_words": guard["flag_words"]}),
        # slow tail (skipped by --quick; keep AFTER the quick rows so
        # baselines.json paths resolve in both modes)
        ("dist_remote_dma", True,
         make_distributed_step(mesh, p, axis="y", x_axis="x", T=T,
                               exchange="remote_dma"),
         G, {"ppermute_wire": wire}),
        ("dist_run_fused", True,
         make_distributed_run(mesh, p, n_blocks=3, axis="y", x_axis="x",
                              T=T, local_kernel="fused"),
         # ONE traced block (lax.fori_loop) — the run's per-block bytes
         # equal the single step's, whatever n_blocks
         G, {"ppermute_wire": wire,
             "pallas_hbm": hbm_bytes_model(Xl + 2 * T, Yl + 2 * T, DZ,
                                           ITEM, "fused", T=T)}),
    ]
    return rungs


def _ledger_rows(mesh, p, spec, smoke):
    ledger_pass = get_pass("movement-ledger")
    coverage_pass = get_pass("model-coverage")
    rows = []
    for name, slow, fn, args, claims in _ledger_rungs(mesh, p, spec):
        if smoke and slow:
            continue
        led = ledger_pass.run(fn, *args)
        report = coverage_pass.run(fn, *args, claims=claims)
        if not report.ok:
            raise SystemExit(
                f"lint gate: model coverage failed on rung {name!r}:\n  "
                + "\n  ".join(str(f) for f in report.failures))
        totals = {k: v for k, v in led.totals().items() if v}
        print(f"ledger.{name}: {totals}")
        rows.append({"rung": name, "categories": totals, "claims": claims,
                     "grand_total": led.grand_total(),
                     "coverage_ok": report.ok})
    return rows


def _retrace_rows(mesh, p, smoke):
    retrace_pass = get_pass("retrace")
    G = _fields(DGRID, 3, salt=10)
    rows = []

    def step_factory(dma_block_index=0):
        fn = make_distributed_step(mesh, p, axis="y", x_axis="x", T=T,
                                   exchange="remote_dma",
                                   dma_block_index=dma_block_index)
        return fn, G

    def run_factory(n_blocks=2, y_tile=None):
        fn = make_distributed_run(mesh, p, n_blocks=n_blocks, axis="y",
                                  x_axis="x", T=T, local_kernel="fused",
                                  y_tile=y_tile)
        return fn, G

    def green_driver(name, factory, perts):
        report = retrace_pass.run(factory, perts)
        for f in report.findings:
            print(f"retrace.{name}: {f}")
        if not report.ok:
            raise SystemExit(
                f"lint gate: retrace detector flagged {name}:\n  "
                + "\n  ".join(str(f) for f in report.findings))
        print(f"retrace.{name}: clean over "
              f"{[pt.knob for pt in perts]}")
        rows.append({"driver": name, "knobs": [pt.knob for pt in perts],
                     "findings": 0, "ok": True})

    green_driver("make_distributed_run", run_factory,
                 [Perturbation("n_blocks", (2, 3), expect="shared"),
                  Perturbation("y_tile", (2, 4), expect="distinct")])

    # the fixture pair: broken driver RED, fixed driver GREEN
    red = retrace_pass.run(
        make_static_parity_driver,
        [Perturbation("block_index", (0, 1), expect="shared")])
    if red.ok or not any(f.kind == "leak" for f in red.findings):
        raise SystemExit(
            "lint gate: the deliberately-broken static-parity fixture was "
            "NOT flagged — the retrace detector lost the PR 5 bug class")
    print(f"retrace.static_parity_fixture: flagged as expected "
          f"({red.findings[0].kind})")
    rows.append({"driver": "static_parity_fixture", "knobs": ["block_index"],
                 "findings": len(red.findings), "ok": False,
                 "expected_red": True})
    green = retrace_pass.run(
        make_traced_parity_driver,
        [Perturbation("block_index", (0, 1), expect="shared")])
    if not green.ok:
        raise SystemExit(
            "lint gate: the FIXED traced-parity fixture was flagged:\n  "
            + "\n  ".join(str(f) for f in green.findings))
    print("retrace.traced_parity_fixture: clean as expected")
    rows.append({"driver": "traced_parity_fixture", "knobs": ["block_index"],
                 "findings": 0, "ok": True})
    # slow tail (full mode only; AFTER the quick rows for path stability)
    if not smoke:
        green_driver("make_distributed_step[remote_dma]", step_factory,
                     [Perturbation("dma_block_index", (0, 1),
                                   expect="shared")])
    return rows


def _vmem_rows():
    budget_pass = get_pass("vmem-budget")
    X, Y, Z = GRID
    DX, DY, DZ = DGRID
    plans = [
        fused_ring_plan(Y, Z, T=T, itemsize=ITEM, y_tile=8, halo=T,
                        context="fused rung rings"),
        distributed_block_plan((DX // 2, DY // 2, DZ), T=T, itemsize=ITEM,
                               local_kernel="fused", exchange="collective",
                               interpret=True, nx=2, ny=2,
                               context="distributed fused rung"),
        serving_ring_plan(Y, Z, batch=BATCH, T=T, itemsize=ITEM, y_tile=8,
                          n_fields=3, context="serving rung slot rings"),
    ]
    rows = []
    for plan in plans:
        budget_pass.run(plan)   # raises VmemBudgetExceeded on overflow
        print(f"vmem.{plan.context}: {plan.total()} B of {plan.budget} B "
              f"({len(plan.buffers)} buffers)")
        rows.append({"context": plan.context, "total_bytes": plan.total(),
                     "budget": plan.budget, "headroom": plan.headroom(),
                     "n_buffers": len(plan.buffers), "fits": plan.fits()})
    # the negative gate: an untiled ring on a tall slab MUST be refused,
    # and the refusal must name the offending buffer
    big = fused_ring_plan(16384, 128, T=8, itemsize=ITEM, y_tile=None,
                          halo=8, context="deliberately oversized ring")
    try:
        budget_pass.run(big)
    except VmemBudgetExceeded as e:
        if "ring" not in str(e):
            raise SystemExit(
                f"lint gate: VmemBudgetExceeded did not name the "
                f"offending buffer: {e}")
        print(f"vmem.oversized: refused as expected ({big.total()} B)")
        rows.append({"context": big.context, "total_bytes": big.total(),
                     "budget": big.budget, "headroom": big.headroom(),
                     "n_buffers": len(big.buffers), "fits": big.fits(),
                     "expected_overflow": True})
    else:
        raise SystemExit(
            f"lint gate: oversized plan ({big.total()} B vs "
            f"{big.budget} B budget) was NOT refused")
    return rows


def _tiling_rows(mesh, p, spec, smoke):
    tiling_pass = get_pass("tiling-contract")
    F = _fields(GRID, 3)
    G = _fields(DGRID, 3, salt=10)
    S = _fields(DGRID, spec.n_fields, salt=20)
    BF = tuple(jnp.stack([f] * BATCH) for f in F)
    pb = AdvectParams(*[jnp.stack([leaf] * BATCH) for leaf in p])
    rungs = [
        ("fused", False,
         lambda u, v, w: advect_fused(u, v, w, p, T=T, interpret=True,
                                      y_tile=8), F),
        ("dist_fused", False,
         make_distributed_step(mesh, p, axis="y", x_axis="x", T=T,
                               local_kernel="fused"), G),
        ("serving_batched", False,
         lambda u, v, w: advect_fused_batched(u, v, w, pb, T=T,
                                              interpret=True, guard=True),
         BF),
        ("spec_fused", True,
         make_distributed_step(mesh, p, axis="y", x_axis="x", T=1,
                               spec=spec, spec_params=p,
                               local_kernel="fused"), S),
    ]
    rows = []
    for name, slow, fn, args in rungs:
        if smoke and slow:
            continue
        report = tiling_pass.run(fn, *args)
        for issue in report.errors:
            print(f"tiling.{name}: ERROR {issue}")
        if report.errors:
            raise SystemExit(
                f"lint gate: tiling contract errors on rung {name!r}:\n  "
                + "\n  ".join(str(i) for i in report.errors))
        print(f"tiling.{name}: {report.kernels} kernels, "
              f"0 errors, {len(report.warnings)} warnings")
        rows.append({"rung": name, "kernels": report.kernels,
                     "errors": 0, "warnings": len(report.warnings)})
    return rows


def run(smoke: bool = None) -> None:
    if smoke is None:
        smoke = os.environ.get("BENCH_SMOKE", "") == "1"
    if jax.device_count() < 4:
        raise SystemExit(
            f"lint gate: needs 4 forced host devices, got "
            f"{jax.device_count()} — is XLA_FLAGS overridden?")
    mesh = make_stencil_mesh(2, 2)
    p = default_params(GRID[2])
    spec = SP.tracer_advection_spec()
    payload = {
        "passes": [{"name": n, "summary": s} for n, s in available()],
        "ledger": _ledger_rows(mesh, p, spec, smoke),
        "retrace": _retrace_rows(mesh, p, smoke),
        "vmem": _vmem_rows(),
        "tiling": _tiling_rows(mesh, p, spec, smoke),
        "contract": "every nonzero ledger category claimed EXACTLY by an "
                    "analytic model term (pallas_control unpriced by "
                    "design); real drivers retrace-free with the broken "
                    "fixture flagged; every shipped VMEM plan within "
                    "VMEM_PER_CORE with oversized plans refused by name; "
                    "zero Pallas tiling-contract errors",
    }
    out_path = os.path.join(os.getcwd(), "BENCH_analysis.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"analysis lint: json written to {out_path}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow rungs (CI smoke mode)")
    ap.add_argument("--list", action="store_true",
                    help="print the registered analysis passes and exit")
    ns = ap.parse_args(argv)
    if ns.list:
        for name, summary in available():
            print(f"{name}: {summary}")
        return
    run(smoke=ns.quick or None)


if __name__ == "__main__":
    main()
